"""XLA-jitted GF(2^8) data plane — the compiled CPU path.

CPU has no Pallas lowering (interpret mode only), so the dispatch policy
routes CPU calls here: the same GF(2^8) formulations as the Pallas
kernels, expressed in jnp and compiled by XLA.  Three strategies, all
byte-identical (cross-checked against the numpy oracle in
``tests/test_dispatch_tune.py``); ``kernels/tune.py`` picks per shape:

* ``bitplane32`` — the bit-plane decomposition packed four bytes per
  uint32 lane: coefficients are < 256, so ``((x >> b) & 0x01010101) * c``
  scales all four byte lanes with no carry between them.  8 fused
  shift/and/mul/xor steps per input row; the default for the small dense
  parity shapes (RS/XOR) where it beats the numpy table path ~5x.
* ``select32`` — 0/1 matrices (RDP blocks and their GF(2) inverses):
  gamma ∈ {0,1} makes gamma·x a select, one masked XOR per input row on
  the same packed uint32 lanes.
* ``table`` — the classic log/exp-gather formulation, one gather row per
  input column; wins when the matrix is large and dense enough that
  8-step bit-plane unrolling dominates.

Entry points mirror the Pallas batched kernels (shared-matrix matmul,
per-item-matrix matmul, per-item-gamma delta) and return *device* arrays
without blocking, so ``submit_*`` engine calls keep their dispatch-at-
submit semantics.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import gf256

_LANES = np.uint32(0x01010101)  # bit b of each packed byte after >> b

# strategy names (the tuner's vocabulary for this path)
BITPLANE32 = "bitplane32"
SELECT32 = "select32"
TABLE = "table"
STRATEGIES = (BITPLANE32, SELECT32, TABLE)


def _as_u8(x) -> jax.Array:
    """uint8 device array; skips ``jnp.asarray`` when already one (the
    conversion machinery costs ~65us/call on CPU — real money against a
    ~50us kernel)."""
    if isinstance(x, jax.Array) and x.dtype == jnp.uint8:
        return x
    return jnp.asarray(x, dtype=jnp.uint8)


def default_strategy(A: np.ndarray) -> str:
    """Heuristic when no tuning entry exists: 0/1 matrices select, dense
    ones run the packed bit-plane (it beat the table path at every CI
    shape we measured — the tuner can still override per key)."""
    return SELECT32 if int(A.max(initial=0)) <= 1 else BITPLANE32


@functools.lru_cache(maxsize=256)
def _mat_dev(kind: str, shape: tuple, buf: bytes) -> jax.Array:
    """Device-resident matrix constants, cached by value: encode/decode
    matrices are few and reused every call, so don't re-transfer (or
    rebuild APOW) per encode."""
    A = np.frombuffer(buf, dtype=np.uint8).reshape(shape)
    if kind == "apow":
        from .gf256_matmul import build_apow
        return jnp.asarray(build_apow(A).astype(np.uint32))
    if kind == "u32":
        return jnp.asarray(A.astype(np.uint32))
    return jnp.asarray(A)


def _pack32(x: jax.Array) -> jax.Array:
    """(..., C) uint8 -> (..., C//4) uint32 byte-lane view (C % 4 == 0)."""
    return jax.lax.bitcast_convert_type(
        x.reshape(x.shape[:-1] + (x.shape[-1] // 4, 4)), jnp.uint32)


def _unpack32(x: jax.Array, C: int) -> jax.Array:
    """Inverse of ``_pack32``."""
    return jax.lax.bitcast_convert_type(x, jnp.uint8).reshape(
        x.shape[:-1] + (C,))


def _xtime_powers(g: jax.Array) -> jax.Array:
    """(..., ) uint32 gamma -> (..., 8) uint32 with out[..., b] = g * 2^b
    over GF(2^8)/0x11D — traced-friendly (no host table)."""
    outs = []
    for _ in range(8):
        outs.append(g)
        g = ((g << 1) ^ jnp.where((g & 0x80) != 0, np.uint32(0x11D),
                                  np.uint32(0))) & np.uint32(0xFF)
    return jnp.stack(outs, axis=-1)


def _pad4(x: jax.Array) -> tuple[jax.Array, int]:
    """Pad the trailing byte axis to a multiple of 4 for the packed
    strategies; returns (padded, original C)."""
    C = x.shape[-1]
    pad = (-C) % 4
    if pad:
        cfg = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, cfg)
    return x, C


# ---------------------------------------------------------------------------
# shared-matrix batched matmul: (m, k) x (B, k, C) -> (B, m, C)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "k"))
def _matmul_bitplane32(apow, data, *, m, k):
    B, _, C = data.shape
    d = _pack32(data)                                     # (B, k, C/4)
    acc = jnp.zeros((B, m, C // 4), jnp.uint32)
    for j in range(k):
        dj = d[:, j]
        for b in range(8):
            bit = (dj >> b) & _LANES                      # (B, C/4)
            acc = acc ^ bit[:, None, :] * apow[None, :, j, b, None]
    return _unpack32(acc, C)


@functools.partial(jax.jit, static_argnames=("m", "k"))
def _matmul_select32(a01, data, *, m, k):
    B, _, C = data.shape
    d = _pack32(data)
    acc = jnp.zeros((B, m, C // 4), jnp.uint32)
    for j in range(k):
        acc = acc ^ a01[None, :, j, None] * d[:, j][:, None, :]
    return _unpack32(acc, C)


@functools.partial(jax.jit, static_argnames=("m", "k"))
def _matmul_table(A, data, *, m, k):
    exp, log, _ = gf256._device_tables()
    la = log[A.astype(jnp.int32)]                         # (m, k)
    B, _, C = data.shape
    acc = jnp.zeros((B, m, C), jnp.uint8)
    for j in range(k):
        dj = data[:, j]                                   # (B, C)
        prod = exp[(la[:, j][None, :, None]
                    + log[dj.astype(jnp.int32)][:, None, :]) % 255]
        prod = jnp.where((A[:, j] == 0)[None, :, None]
                         | (dj == 0)[:, None, :], jnp.uint8(0), prod)
        acc = acc ^ prod
    return acc


def matmul_batched(A: np.ndarray, data, *, strategy: str | None = None):
    """XLA twin of ``gf256_matmul_batched``: (B, k, C) -> (B, m, C)."""
    A = np.asarray(A, dtype=np.uint8)
    m, k = A.shape
    data = _as_u8(data)
    B, kd, C = data.shape
    assert kd == k, (data.shape, k)
    if B == 0 or m == 0:
        return jnp.zeros((B, m, C), jnp.uint8)
    if strategy is None or (strategy == SELECT32 and int(A.max()) > 1):
        strategy = default_strategy(A)
    if strategy == TABLE:
        return _matmul_table(_mat_dev("u8", A.shape, A.tobytes()), data,
                             m=m, k=k)
    data, C = _pad4(data)
    if strategy == SELECT32:
        out = _matmul_select32(_mat_dev("u32", A.shape, A.tobytes()), data,
                               m=m, k=k)
    else:
        out = _matmul_bitplane32(_mat_dev("apow", A.shape, A.tobytes()),
                                 data, m=m, k=k)
    return out if out.shape[-1] == C else out[:, :, :C]


# ---------------------------------------------------------------------------
# single-stripe 2D matmul: (m, k) x (k, C) -> (m, C)
# Dedicated jits: the batched entry at B=1 pays an eager expand/squeeze
# per call, which dominates at paper chunk sizes on the CPU dispatcher.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "k"))
def _matmul2d_bitplane32(apow, data, *, m, k):
    C = data.shape[-1]
    d = _pack32(data)                                     # (k, C/4)
    acc = jnp.zeros((m, C // 4), jnp.uint32)
    for j in range(k):
        dj = d[j]
        for b in range(8):
            bit = (dj >> b) & _LANES                      # (C/4,)
            acc = acc ^ bit[None, :] * apow[:, j, b, None]
    return _unpack32(acc, C)


@functools.partial(jax.jit, static_argnames=("m", "k"))
def _matmul2d_select32(a01, data, *, m, k):
    C = data.shape[-1]
    d = _pack32(data)
    acc = jnp.zeros((m, C // 4), jnp.uint32)
    for j in range(k):
        acc = acc ^ a01[:, j, None] * d[j][None, :]
    return _unpack32(acc, C)


@functools.partial(jax.jit, static_argnames=("m", "k"))
def _matmul2d_table(A, data, *, m, k):
    exp, log, _ = gf256._device_tables()
    la = log[A.astype(jnp.int32)]                         # (m, k)
    C = data.shape[-1]
    acc = jnp.zeros((m, C), jnp.uint8)
    for j in range(k):
        dj = data[j]                                      # (C,)
        prod = exp[(la[:, j][:, None]
                    + log[dj.astype(jnp.int32)][None, :]) % 255]
        prod = jnp.where((A[:, j] == 0)[:, None]
                         | (dj == 0)[None, :], jnp.uint8(0), prod)
        acc = acc ^ prod
    return acc


def matmul(A: np.ndarray, data, *, strategy: str | None = None):
    """XLA twin of ``gf256_matmul``: (m, k) x (k, C) -> (m, C)."""
    A = np.asarray(A, dtype=np.uint8)
    m, k = A.shape
    data = _as_u8(data)
    C = data.shape[-1]
    if m == 0:
        return jnp.zeros((m, C), jnp.uint8)
    if strategy is None or (strategy == SELECT32 and int(A.max()) > 1):
        strategy = default_strategy(A)
    if strategy == TABLE:
        return _matmul2d_table(_mat_dev("u8", A.shape, A.tobytes()), data,
                               m=m, k=k)
    data, C = _pad4(data)
    if strategy == SELECT32:
        out = _matmul2d_select32(_mat_dev("u32", A.shape, A.tobytes()),
                                 data, m=m, k=k)
    else:
        out = _matmul2d_bitplane32(_mat_dev("apow", A.shape, A.tobytes()),
                                   data, m=m, k=k)
    return out if out.shape[-1] == C else out[:, :C]


# ---------------------------------------------------------------------------
# per-item-matrix batched matmul: (B, O, J) x (B, J, C) -> (B, O, C)
# (r > 1 delta matrices, fused parity folds)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("o", "j", "fold"))
def _per_item_bitplane32(Ms, data, parity, *, o, j, fold):
    B, _, C = data.shape
    d = _pack32(data)
    apow = _xtime_powers(Ms.astype(jnp.uint32))           # (B, O, J, 8)
    acc = jnp.zeros((B, o, C // 4), jnp.uint32)
    for jj in range(j):
        dj = d[:, jj]
        for b in range(8):
            bit = (dj >> b) & _LANES
            acc = acc ^ bit[:, None, :] * apow[:, :, jj, b, None]
    out = _unpack32(acc, C)
    return parity ^ out if fold else out


@functools.partial(jax.jit, static_argnames=("o", "j", "fold"))
def _per_item_select32(Ms, data, parity, *, o, j, fold):
    B, _, C = data.shape
    d = _pack32(data)
    m01 = Ms.astype(jnp.uint32)
    acc = jnp.zeros((B, o, C // 4), jnp.uint32)
    for jj in range(j):
        acc = acc ^ m01[:, :, jj, None] * d[:, jj][:, None, :]
    out = _unpack32(acc, C)
    return parity ^ out if fold else out


@functools.partial(jax.jit, static_argnames=("o", "j", "fold"))
def _per_item_table(Ms, data, parity, *, o, j, fold):
    exp, log, _ = gf256._device_tables()
    B, _, C = data.shape
    lm = log[Ms.astype(jnp.int32)]                        # (B, O, J)
    acc = jnp.zeros((B, o, C), jnp.uint8)
    for jj in range(j):
        dj = data[:, jj]
        prod = exp[(lm[:, :, jj, None]
                    + log[dj.astype(jnp.int32)][:, None, :]) % 255]
        prod = jnp.where((Ms[:, :, jj, None] == 0)
                         | (dj == 0)[:, None, :], jnp.uint8(0), prod)
        acc = acc ^ prod
    return parity ^ acc if fold else acc


def matmul_per_item(Ms, blocks, parity=None, *, strategy: str | None = None):
    """Per-item matrices: (B, O, J) ∘ (B, J, C) -> (B, O, C).

    ``parity`` (B, O, C), when given, is XORed in inside the same jit —
    the fused delta-apply / seal-fold path (no separate device round
    trip for the fold)."""
    Ms = np.asarray(Ms, dtype=np.uint8) if isinstance(Ms, np.ndarray) \
        else jnp.asarray(Ms, dtype=jnp.uint8)
    blocks = _as_u8(blocks)
    B, O, J = Ms.shape
    C = blocks.shape[2]
    if B == 0 or O == 0:
        return jnp.zeros((B, O, C), jnp.uint8)
    if strategy is None or (strategy == SELECT32
                            and int(np.asarray(Ms).max()) > 1):
        strategy = default_strategy(np.asarray(Ms))
    fold = parity is not None
    par = (_as_u8(parity) if fold
           else jnp.zeros((), jnp.uint8))
    if strategy == TABLE:
        return _per_item_table(jnp.asarray(Ms), blocks, par,
                               o=O, j=J, fold=fold)
    blocks, C = _pad4(blocks)
    if fold:
        par, _ = _pad4(par)
    fn = _per_item_select32 if strategy == SELECT32 else _per_item_bitplane32
    out = fn(jnp.asarray(Ms), blocks, par, o=O, j=J, fold=fold)
    return out[:, :, :C]


# ---------------------------------------------------------------------------
# per-item-gamma delta: gammas (B, m), xor (B, C) -> (B, m, C)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "fold"))
def _delta_bitplane32(gammas, xor, parity, *, m, fold):
    B, C = xor.shape
    x = _pack32(xor)                                      # (B, C/4)
    gpow = _xtime_powers(gammas.astype(jnp.uint32))       # (B, m, 8)
    acc = jnp.zeros((B, m, C // 4), jnp.uint32)
    for b in range(8):
        bit = (x >> b) & _LANES
        acc = acc ^ bit[:, None, :] * gpow[:, :, b, None]
    out = _unpack32(acc, C)
    return parity ^ out if fold else out


@functools.partial(jax.jit, static_argnames=("m",))
def _delta2d_bitplane32(gammas, old, new, parity, *, m):
    x = _pack32(old ^ new)                                # (C/4,)
    gpow = _xtime_powers(gammas.astype(jnp.uint32))       # (m, 8)
    C = old.shape[-1]
    acc = jnp.zeros((m, C // 4), jnp.uint32)
    for b in range(8):
        bit = (x >> b) & _LANES
        acc = acc ^ bit[None, :] * gpow[:, b, None]
    return parity ^ _unpack32(acc, C)


def delta_single(parity, gammas, old, new):
    """Single-row fused P' = P ^ gamma (old ^ new): the XOR and the fold
    both happen inside one jit (no eager expand/squeeze at B=1)."""
    parity = _as_u8(parity)
    m = parity.shape[0]
    C = parity.shape[-1]
    if m == 0:
        return parity
    old, _ = _pad4(_as_u8(old))
    new, _ = _pad4(_as_u8(new))
    par, _ = _pad4(parity)
    out = _delta2d_bitplane32(jnp.asarray(gammas, dtype=jnp.uint32),
                              old, new, par, m=m)
    return out if out.shape[-1] == C else out[:, :C]


def delta_batched(gammas, xors, parity=None):
    """XLA twin of ``delta_apply_batched``: per-item gamma rows, 8 packed
    bit-plane steps; ``parity`` folds in-jit when given."""
    xors = _as_u8(xors)
    gammas = jnp.asarray(gammas, dtype=jnp.uint32)
    B, m = gammas.shape
    C = xors.shape[1]
    if B == 0 or m == 0:
        return jnp.zeros((B, m, C), jnp.uint8)
    fold = parity is not None
    xors, C = _pad4(xors)
    par = jnp.zeros((), jnp.uint8)
    if fold:
        par, _ = _pad4(_as_u8(parity))
    out = _delta_bitplane32(gammas, xors, par, m=m, fold=fold)
    return out[:, :, :C]
