"""Backend-aware kernel dispatch policy (the compiled data plane's seam).

Every coding kernel used to decide how to run with a scattered
``interpret = jax.default_backend() != "tpu"`` check — which silently ran
*interpret-mode* Pallas on GPU (where the Triton lowering compiles fine)
and on every CPU CI runner (where interpret mode is ~15x slower than
numpy).  This module is the single policy those call sites share now:

* **TPU / GPU** -> compiled Pallas (``interpret=False``): the batched
  grids lower natively (Mosaic on TPU, Triton on GPU).
* **CPU** -> an XLA-jitted GF(2^8) path (``xla_gf256``): bit-plane /
  log-exp-table formulations compiled by XLA CPU — no interpret tax, and
  measurably faster than the numpy oracle (see ``benchmarks/
  kernels_bench.py`` compiled-vs-interpret-vs-numpy rows).  Kernels with
  no XLA twin (none today) would fall back to interpret explicitly.
* **Interpret mode** is an escape hatch only: ``$MEMEC_INTERPRET=1``
  forces it everywhere (debugging kernel bodies on any backend), and an
  explicit ``interpret=True`` argument forces it per call (tests).

``decide()`` returns the chosen path; engines surface it through
``CodingEngine.describe()``/``stats()`` so a run can always answer "did
I actually compile?".  ``benchmarks/kernels_bench.py`` fails loudly if
the policy lands on interpret without ``$MEMEC_INTERPRET`` being set.
"""
from __future__ import annotations

import dataclasses
import os

import jax

# dispatch paths
PALLAS = "pallas-compiled"   # pl.pallas_call, interpret=False
XLA = "xla-compiled"         # jitted jnp GF(2^8) formulation (CPU)
INTERPRET = "interpret"      # pl.pallas_call, interpret=True

_TRUTHY = ("1", "true", "yes", "on")


@dataclasses.dataclass(frozen=True)
class Decision:
    """How a kernel call should run.

    ``path``: PALLAS | XLA | INTERPRET; ``interpret``: the flag handed to
    ``pl.pallas_call`` when the path is Pallas-shaped (PALLAS/INTERPRET —
    XLA-path callers never reach a ``pallas_call``).
    """
    path: str

    @property
    def interpret(self) -> bool:
        return self.path == INTERPRET

    @property
    def compiled(self) -> bool:
        return self.path != INTERPRET


def backend() -> str:
    """The active jax backend (``cpu`` | ``gpu`` | ``tpu``)."""
    return jax.default_backend()


def interpret_forced() -> bool:
    """``$MEMEC_INTERPRET`` truthy — the explicit interpret escape hatch
    (read per call so tests can flip it with monkeypatch)."""
    return os.environ.get("MEMEC_INTERPRET", "").strip().lower() in _TRUTHY


def decide(interpret: bool | None = None, *, xla_ok: bool = True) -> Decision:
    """Resolve the dispatch path for one kernel call.

    ``interpret`` is the per-call override kernels have always accepted:
    ``True`` forces interpret mode, ``False`` forces compiled Pallas
    (raising on backends with no Pallas lowering — an explicit ask), and
    ``None`` defers to the policy.  ``xla_ok=False`` marks kernels that
    have no XLA twin; on CPU those fall back to interpret.
    """
    if interpret is True:
        return Decision(INTERPRET)
    if interpret is False:
        return Decision(PALLAS)
    if interpret_forced():
        return Decision(INTERPRET)
    if backend() in ("tpu", "gpu"):
        return Decision(PALLAS)
    return Decision(XLA) if xla_ok else Decision(INTERPRET)


def describe() -> dict:
    """Policy snapshot for ``engine.describe()`` / bench provenance."""
    d = decide()
    return {
        "backend": backend(),
        "path": d.path,
        "interpret_forced": interpret_forced(),
    }
