"""Pallas TPU kernel: GF(2^8) matrix multiply (stripe encode/decode).

TPU adaptation (DESIGN.md §4): the CPU path (ISA-L) gathers 16-entry PSHUFB
tables per product — byte gathers don't vectorize on the TPU VPU.  Instead
we use the bit-plane decomposition

    gamma * x  =  XOR_{b : bit b of x set}  (gamma * 2^b)

so a stripe encode P[m,C] = A[m,k] (*) D[k,C] becomes, per C-tile:

    P[r] = XOR_{i<k, b<8}  ((D[i] >> b) & 1) * APOW[r,i,b]

where APOW[r,i,b] = A[r,i] * 2^b in GF(2^8) is a tiny host-precomputed
table.  The kernel body is pure shift/and/multiply/xor on int32 lanes —
fully VPU-vectorizable, no gathers, no MXU.  m*k*8 fused ops per tile
(e.g. 128 for (n,k)=(10,8)): the op is HBM-bandwidth-bound by design.

Tiling: grid over the byte axis; D tile (k, BC) and P tile (m, BC) live in
VMEM; APOW (m,k,8 int32) is broadcast to every grid step.  BC=2048 keeps
the working set (k+m)*BC + 32*m*k ~ 20-40 KB, far under the ~16 MB VMEM
budget, and 2048 = 16 lanes * 128 keeps the last dim lane-aligned.

Large matrices (PR 5): fully unrolling the (m, k, 8) product is only
sane for small dense parity shapes; the RDP *block* representation is
(m*r, k*r) — e.g. (32, 128) for (10,8) at p=17 — and its decode inverse
is (k*r, k*r).  Above ``MAX_UNROLL_OPS`` the batched entry point
switches to column-loop kernels whose body is O(k) vector steps over
(m, BC) lanes; pure-XOR 0/1 matrices (RDP blocks, XOR, and their decode
inverses — GF(2) systems stay 0/1 under inversion) additionally drop
the bit-plane loop, since gamma ∈ {0,1} makes gamma·x a select.  This
is what lets the engine route RDP through the batched Pallas grid
natively instead of falling back to the jnp path.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import gf256

DEFAULT_BLOCK_C = 2048

# beyond this many fused ops (m*k*8) the per-element unrolled kernel
# body becomes pathological; switch to the column-loop variants
MAX_UNROLL_OPS = 1024


def build_apow(A: np.ndarray) -> np.ndarray:
    """APOW[r,i,b] = A[r,i] * 2^b over GF(2^8), int32 (m,k,8)."""
    A = np.asarray(A, dtype=np.uint8)
    pow2 = np.array([1 << b for b in range(8)], dtype=np.uint8)
    return gf256.MUL_TABLE[A[..., None], pow2[None, None, :]].astype(np.int32)


def _gf_matmul_kernel(apow_ref, d_ref, o_ref, *, m: int, k: int):
    d = d_ref[...].astype(jnp.int32)                      # (k, BC)
    acc = [jnp.zeros(d.shape[1:], jnp.int32) for _ in range(m)]
    for i in range(k):
        di = d[i]
        for b in range(8):
            bit = (di >> b) & 1                           # (BC,) 0/1
            for r in range(m):
                acc[r] = acc[r] ^ (bit * apow_ref[r, i, b])
    o_ref[...] = jnp.stack(acc).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("m", "k", "block_c", "interpret"))
def _gf_matmul_call(apow, data, *, m, k, block_c, interpret):
    C = data.shape[1]
    grid = (C // block_c,)
    return pl.pallas_call(
        functools.partial(_gf_matmul_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k, 8), lambda c: (0, 0, 0)),
            pl.BlockSpec((k, block_c), lambda c: (0, c)),
        ],
        out_specs=pl.BlockSpec((m, block_c), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct((m, C), jnp.uint8),
        interpret=interpret,
    )(apow, data)


def _gf_matmul_batched_kernel(apow_ref, d_ref, o_ref, *, m: int, k: int):
    d = d_ref[0].astype(jnp.int32)                        # (k, BC)
    acc = [jnp.zeros(d.shape[1:], jnp.int32) for _ in range(m)]
    for i in range(k):
        di = d[i]
        for b in range(8):
            bit = (di >> b) & 1
            for r in range(m):
                acc[r] = acc[r] ^ (bit * apow_ref[r, i, b])
    o_ref[0] = jnp.stack(acc).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("m", "k", "block_c", "interpret"))
def _gf_matmul_batched_call(apow, data, *, m, k, block_c, interpret):
    B, _, C = data.shape
    grid = (B, C // block_c)
    return pl.pallas_call(
        functools.partial(_gf_matmul_batched_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k, 8), lambda b, c: (0, 0, 0)),
            pl.BlockSpec((1, k, block_c), lambda b, c: (b, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, m, block_c), lambda b, c: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, m, C), jnp.uint8),
        interpret=interpret,
    )(apow, data)


def _gf_matmul_cols_kernel(apow_ref, d_ref, o_ref, *, m: int, k: int):
    """Column-loop body for large matrices: k*8 vectorized (m, BC)
    accumulation steps instead of m*k*8 scalar-coefficient ops."""
    d = d_ref[0].astype(jnp.int32)                        # (k, BC)
    acc = jnp.zeros((m, d.shape[1]), jnp.int32)
    for j in range(k):
        dj = d[j]
        for b in range(8):
            bit = (dj >> b) & 1                           # (BC,)
            acc = acc ^ (bit[None, :] * apow_ref[:, j, b][:, None])
    o_ref[0] = acc.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("m", "k", "block_c", "interpret"))
def _gf_matmul_cols_call(apow, data, *, m, k, block_c, interpret):
    B, _, C = data.shape
    grid = (B, C // block_c)
    return pl.pallas_call(
        functools.partial(_gf_matmul_cols_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k, 8), lambda b, c: (0, 0, 0)),
            pl.BlockSpec((1, k, block_c), lambda b, c: (b, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, m, block_c), lambda b, c: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, m, C), jnp.uint8),
        interpret=interpret,
    )(apow, data)


def _gf01_matmul_kernel(a_ref, d_ref, o_ref, *, m: int, k: int):
    """0/1 matrices (pure-XOR codes): gamma·x is a select, so the
    bit-plane loop vanishes — k XOR-select steps over (m, BC) lanes."""
    d = d_ref[0].astype(jnp.int32)                        # (k, BC)
    acc = jnp.zeros((m, d.shape[1]), jnp.int32)
    for j in range(k):
        acc = acc ^ (a_ref[:, j][:, None] * d[j][None, :])
    o_ref[0] = acc.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("m", "k", "block_c", "interpret"))
def _gf01_matmul_call(a01, data, *, m, k, block_c, interpret):
    B, _, C = data.shape
    grid = (B, C // block_c)
    return pl.pallas_call(
        functools.partial(_gf01_matmul_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda b, c: (0, 0)),
            pl.BlockSpec((1, k, block_c), lambda b, c: (b, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, m, block_c), lambda b, c: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, m, C), jnp.uint8),
        interpret=interpret,
    )(a01, data)


def gf256_matmul_batched(A: np.ndarray, data: jax.Array, *,
                         block_c: int = DEFAULT_BLOCK_C,
                         interpret: bool | None = None) -> jax.Array:
    """Batched A (*) data over GF(2^8): one matrix, a whole batch of stripes.

    A: (m, k) uint8 shared across the batch; data: (B, k, C) uint8 ->
    (B, m, C).  The grid runs (batch, C-tiles) so every stripe's tiles are
    independent grid steps — the batched analogue of `gf256_matmul`.

    Works for any matrix size: small dense matrices (RS/XOR parity
    shapes) take the fully-unrolled kernel; larger ones — the RDP block
    representation and its decode inverses — take the column-loop
    kernels, with 0/1 matrices on the bit-plane-free XOR-select body.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    A = np.asarray(A, dtype=np.uint8)
    m, k = A.shape
    data = jnp.asarray(data, dtype=jnp.uint8)
    B, kd, C = data.shape
    assert kd == k, (data.shape, k)
    if B == 0 or m == 0:
        return jnp.zeros((B, m, C), jnp.uint8)
    block_c = min(block_c, _round_up(C, 128))
    Cp = _round_up(C, block_c)
    if Cp != C:
        data = jnp.pad(data, ((0, 0), (0, 0), (0, Cp - C)))
    if m * k * 8 <= MAX_UNROLL_OPS:
        apow = jnp.asarray(build_apow(A))
        out = _gf_matmul_batched_call(apow, data, m=m, k=k, block_c=block_c,
                                      interpret=interpret)
    elif int(A.max()) <= 1:
        out = _gf01_matmul_call(jnp.asarray(A.astype(np.int32)), data,
                                m=m, k=k, block_c=block_c,
                                interpret=interpret)
    else:
        apow = jnp.asarray(build_apow(A))
        out = _gf_matmul_cols_call(apow, data, m=m, k=k, block_c=block_c,
                                   interpret=interpret)
    return out[:, :, :C]


def gf256_matmul(A: np.ndarray, data: jax.Array, *,
                 block_c: int = DEFAULT_BLOCK_C,
                 interpret: bool | None = None) -> jax.Array:
    """Compute A (*) data over GF(2^8).

    A: (m, k) uint8 host matrix (encode parity matrix or decode inverse);
    data: (k, C) uint8.  C is padded to a multiple of block_c internally.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    A = np.asarray(A, dtype=np.uint8)
    m, k = A.shape
    data = jnp.asarray(data, dtype=jnp.uint8)
    assert data.shape[0] == k, (data.shape, k)
    C = data.shape[1]
    block_c = min(block_c, _round_up(C, 128))
    Cp = _round_up(C, block_c)
    if Cp != C:
        data = jnp.pad(data, ((0, 0), (0, Cp - C)))
    apow = jnp.asarray(build_apow(A))
    out = _gf_matmul_call(apow, data, m=m, k=k, block_c=block_c,
                          interpret=interpret)
    return out[:, :C]


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult
