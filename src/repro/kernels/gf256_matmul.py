"""Pallas TPU kernel: GF(2^8) matrix multiply (stripe encode/decode).

TPU adaptation (DESIGN.md §4): the CPU path (ISA-L) gathers 16-entry PSHUFB
tables per product — byte gathers don't vectorize on the TPU VPU.  Instead
we use the bit-plane decomposition

    gamma * x  =  XOR_{b : bit b of x set}  (gamma * 2^b)

so a stripe encode P[m,C] = A[m,k] (*) D[k,C] becomes, per C-tile:

    P[r] = XOR_{i<k, b<8}  ((D[i] >> b) & 1) * APOW[r,i,b]

where APOW[r,i,b] = A[r,i] * 2^b in GF(2^8) is a tiny host-precomputed
table.  The kernel body is pure shift/and/multiply/xor on int32 lanes —
fully VPU-vectorizable, no gathers, no MXU.  m*k*8 fused ops per tile
(e.g. 128 for (n,k)=(10,8)): the op is HBM-bandwidth-bound by design.

Tiling: grid over the byte axis; D tile (k, BC) and P tile (m, BC) live in
VMEM; APOW (m,k,8 int32) is broadcast to every grid step.  BC=2048 keeps
the working set (k+m)*BC + 32*m*k ~ 20-40 KB, far under the ~16 MB VMEM
budget, and 2048 = 16 lanes * 128 keeps the last dim lane-aligned.

Large matrices (PR 5): fully unrolling the (m, k, 8) product is only
sane for small dense parity shapes; the RDP *block* representation is
(m*r, k*r) — e.g. (32, 128) for (10,8) at p=17 — and its decode inverse
is (k*r, k*r).  Above ``MAX_UNROLL_OPS`` the batched entry point
switches to column-loop kernels whose body is O(k) vector steps over
(m, BC) lanes; pure-XOR 0/1 matrices (RDP blocks, XOR, and their decode
inverses — GF(2) systems stay 0/1 under inversion) additionally drop
the bit-plane loop, since gamma ∈ {0,1} makes gamma·x a select.  This
is what lets the engine route RDP through the batched Pallas grid
natively instead of falling back to the jnp path.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import gf256
from repro.kernels import dispatch

DEFAULT_BLOCK_C = 2048

# heuristic fallback when no tuning entry covers the shape: beyond this
# many fused ops (m*k*8) the per-element unrolled kernel body becomes
# pathological and the column-loop variants take over.  The tuner
# (kernels/tune.py) overrides this per (k, m, chunk, batch) key.
MAX_UNROLL_OPS = 1024

# Pallas-path strategy names (the tuner's vocabulary; the XLA CPU path
# has its own set in xla_gf256.STRATEGIES)
PALLAS_STRATEGIES = ("unroll", "cols", "gf01")


def build_apow(A: np.ndarray) -> np.ndarray:
    """APOW[r,i,b] = A[r,i] * 2^b over GF(2^8), int32 (m,k,8)."""
    A = np.asarray(A, dtype=np.uint8)
    pow2 = np.array([1 << b for b in range(8)], dtype=np.uint8)
    return gf256.MUL_TABLE[A[..., None], pow2[None, None, :]].astype(np.int32)


def _gf_matmul_kernel(apow_ref, d_ref, o_ref, *, m: int, k: int):
    d = d_ref[...].astype(jnp.int32)                      # (k, BC)
    acc = [jnp.zeros(d.shape[1:], jnp.int32) for _ in range(m)]
    for i in range(k):
        di = d[i]
        for b in range(8):
            bit = (di >> b) & 1                           # (BC,) 0/1
            for r in range(m):
                acc[r] = acc[r] ^ (bit * apow_ref[r, i, b])
    o_ref[...] = jnp.stack(acc).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("m", "k", "block_c", "interpret"))
def _gf_matmul_call(apow, data, *, m, k, block_c, interpret):
    C = data.shape[1]
    grid = (C // block_c,)
    return pl.pallas_call(
        functools.partial(_gf_matmul_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k, 8), lambda c: (0, 0, 0)),
            pl.BlockSpec((k, block_c), lambda c: (0, c)),
        ],
        out_specs=pl.BlockSpec((m, block_c), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct((m, C), jnp.uint8),
        interpret=interpret,
    )(apow, data)


def _gf_matmul_batched_kernel(apow_ref, d_ref, o_ref, *, m: int, k: int):
    d = d_ref[0].astype(jnp.int32)                        # (k, BC)
    acc = [jnp.zeros(d.shape[1:], jnp.int32) for _ in range(m)]
    for i in range(k):
        di = d[i]
        for b in range(8):
            bit = (di >> b) & 1
            for r in range(m):
                acc[r] = acc[r] ^ (bit * apow_ref[r, i, b])
    o_ref[0] = jnp.stack(acc).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("m", "k", "block_c", "interpret"))
def _gf_matmul_batched_call(apow, data, *, m, k, block_c, interpret):
    B, _, C = data.shape
    grid = (B, C // block_c)
    return pl.pallas_call(
        functools.partial(_gf_matmul_batched_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k, 8), lambda b, c: (0, 0, 0)),
            pl.BlockSpec((1, k, block_c), lambda b, c: (b, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, m, block_c), lambda b, c: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, m, C), jnp.uint8),
        interpret=interpret,
    )(apow, data)


def _gf_matmul_cols_kernel(apow_ref, d_ref, o_ref, *, m: int, k: int):
    """Column-loop body for large matrices: k*8 vectorized (m, BC)
    accumulation steps instead of m*k*8 scalar-coefficient ops."""
    d = d_ref[0].astype(jnp.int32)                        # (k, BC)
    acc = jnp.zeros((m, d.shape[1]), jnp.int32)
    for j in range(k):
        dj = d[j]
        for b in range(8):
            bit = (dj >> b) & 1                           # (BC,)
            acc = acc ^ (bit[None, :] * apow_ref[:, j, b][:, None])
    o_ref[0] = acc.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("m", "k", "block_c", "interpret"))
def _gf_matmul_cols_call(apow, data, *, m, k, block_c, interpret):
    B, _, C = data.shape
    grid = (B, C // block_c)
    return pl.pallas_call(
        functools.partial(_gf_matmul_cols_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k, 8), lambda b, c: (0, 0, 0)),
            pl.BlockSpec((1, k, block_c), lambda b, c: (b, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, m, block_c), lambda b, c: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, m, C), jnp.uint8),
        interpret=interpret,
    )(apow, data)


def _gf01_matmul_kernel(a_ref, d_ref, o_ref, *, m: int, k: int):
    """0/1 matrices (pure-XOR codes): gamma·x is a select, so the
    bit-plane loop vanishes — k XOR-select steps over (m, BC) lanes."""
    d = d_ref[0].astype(jnp.int32)                        # (k, BC)
    acc = jnp.zeros((m, d.shape[1]), jnp.int32)
    for j in range(k):
        acc = acc ^ (a_ref[:, j][:, None] * d[j][None, :])
    o_ref[0] = acc.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("m", "k", "block_c", "interpret"))
def _gf01_matmul_call(a01, data, *, m, k, block_c, interpret):
    B, _, C = data.shape
    grid = (B, C // block_c)
    return pl.pallas_call(
        functools.partial(_gf01_matmul_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda b, c: (0, 0)),
            pl.BlockSpec((1, k, block_c), lambda b, c: (b, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, m, block_c), lambda b, c: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, m, C), jnp.uint8),
        interpret=interpret,
    )(a01, data)


def gf256_matmul_batched(A: np.ndarray, data: jax.Array, *,
                         block_c: int | None = None,
                         strategy: str | None = None,
                         interpret: bool | None = None) -> jax.Array:
    """Batched A (*) data over GF(2^8): one matrix, a whole batch of stripes.

    A: (m, k) uint8 shared across the batch; data: (B, k, C) uint8 ->
    (B, m, C).  The grid runs (batch, C-tiles) so every stripe's tiles are
    independent grid steps — the batched analogue of `gf256_matmul`.

    Dispatch: the path comes from ``kernels.dispatch`` (compiled Pallas
    on TPU/GPU, the XLA-jitted ``xla_gf256`` formulations on CPU,
    interpret only when forced).  ``strategy``/``block_c`` default to the
    tuning cache for this (path, shape) key, then to the MAX_UNROLL_OPS
    heuristic: small dense matrices (RS/XOR parity shapes) take the
    fully-unrolled kernel; larger ones — the RDP block representation and
    its decode inverses — take the column-loop kernels, with 0/1 matrices
    on the bit-plane-free XOR-select body.
    """
    from repro.kernels import tune, xla_gf256
    A = np.asarray(A, dtype=np.uint8)
    m, k = A.shape
    data = jnp.asarray(data, dtype=jnp.uint8)
    B, kd, C = data.shape
    assert kd == k, (data.shape, k)
    if B == 0 or m == 0:
        return jnp.zeros((B, m, C), jnp.uint8)
    dec = dispatch.decide(interpret)
    cls = "01" if int(A.max(initial=0)) <= 1 else "gf"
    if strategy is None or block_c is None:
        entry = tune.lookup("matmul", dec.path, k=k, m=m, chunk=C,
                            batch=B, cls=cls)
        if entry:
            strategy = strategy or entry.get("strategy")
            if block_c is None and entry.get("block_c"):
                block_c = entry["block_c"]
    if dec.path == dispatch.XLA:
        s = strategy if strategy in xla_gf256.STRATEGIES else None
        return xla_gf256.matmul_batched(A, data, strategy=s)
    block_c = min(block_c or DEFAULT_BLOCK_C, _round_up(C, 128))
    Cp = _round_up(C, block_c)
    if Cp != C:
        data = jnp.pad(data, ((0, 0), (0, 0), (0, Cp - C)))
    if strategy not in PALLAS_STRATEGIES:
        strategy = ("unroll" if m * k * 8 <= MAX_UNROLL_OPS
                    else "gf01" if cls == "01" else "cols")
    if strategy == "gf01" and cls != "01":
        strategy = "cols"
    if strategy == "unroll":
        apow = jnp.asarray(build_apow(A))
        out = _gf_matmul_batched_call(apow, data, m=m, k=k, block_c=block_c,
                                      interpret=dec.interpret)
    elif strategy == "gf01":
        out = _gf01_matmul_call(jnp.asarray(A.astype(np.int32)), data,
                                m=m, k=k, block_c=block_c,
                                interpret=dec.interpret)
    else:
        apow = jnp.asarray(build_apow(A))
        out = _gf_matmul_cols_call(apow, data, m=m, k=k, block_c=block_c,
                                   interpret=dec.interpret)
    return out[:, :, :C]


def gf256_matmul(A: np.ndarray, data: jax.Array, *,
                 block_c: int | None = None,
                 interpret: bool | None = None) -> jax.Array:
    """Compute A (*) data over GF(2^8).

    A: (m, k) uint8 host matrix (encode parity matrix or decode inverse);
    data: (k, C) uint8.  C is padded to a multiple of block_c internally.
    Dispatches like ``gf256_matmul_batched`` (the XLA CPU path runs it as
    a batch of one).
    """
    from repro.kernels import tune, xla_gf256
    A = np.asarray(A, dtype=np.uint8)
    m, k = A.shape
    data = xla_gf256._as_u8(data)
    assert data.shape[0] == k, (data.shape, k)
    C = data.shape[1]
    dec = dispatch.decide(interpret)
    if dec.path == dispatch.XLA:
        ent = tune.lookup("matmul", dec.path, k=k, m=m, chunk=C, batch=1,
                          cls=tune.matrix_cls(A))
        s = ent.get("strategy") if ent else None
        return xla_gf256.matmul(
            A, data, strategy=s if s in xla_gf256.STRATEGIES else None)
    block_c = min(block_c or DEFAULT_BLOCK_C, _round_up(C, 128))
    Cp = _round_up(C, block_c)
    if Cp != C:
        data = jnp.pad(data, ((0, 0), (0, Cp - C)))
    apow = jnp.asarray(build_apow(A))
    out = _gf_matmul_call(apow, data, m=m, k=k, block_c=block_c,
                          interpret=dec.interpret)
    return out[:, :C]


def _per_item_acc(m_ref, d, o: int, j: int, is01: bool):
    """Accumulate M_b (*) D_b for one grid step's (O, J) matrix tile.

    Coefficients are traced (each batch item carries its own matrix), so
    gamma powers come from in-kernel xtime steps like delta_update's —
    no host APOW table.  0/1 matrices skip the bit-plane loop entirely.
    """
    acc = jnp.zeros((o, d.shape[1]), jnp.int32)
    for jj in range(j):
        x = d[jj]                                         # (BC,)
        if is01:
            acc = acc ^ (m_ref[0, :, jj][:, None] * x[None, :])
        else:
            g = m_ref[0, :, jj].astype(jnp.int32)         # (O,)
            for b in range(8):
                acc = acc ^ (((x >> b) & 1)[None, :] * g[:, None])
                g = ((g << 1) ^ jnp.where((g & 0x80) != 0, 0x11D, 0)) & 0xFF
    return acc


def _per_item_kernel(m_ref, d_ref, o_ref, *, o: int, j: int, is01: bool):
    d = d_ref[0].astype(jnp.int32)                        # (J, BC)
    o_ref[0] = _per_item_acc(m_ref, d, o, j, is01).astype(jnp.uint8)


def _per_item_fold_kernel(m_ref, p_ref, d_ref, o_ref, *, o: int, j: int,
                          is01: bool):
    d = d_ref[0].astype(jnp.int32)
    o_ref[0] = p_ref[0] ^ _per_item_acc(m_ref, d, o, j, is01).astype(jnp.uint8)


@functools.partial(jax.jit,
                   static_argnames=("o", "j", "block_c", "interpret", "is01"))
def _per_item_call(Ms, data, *, o, j, block_c, interpret, is01):
    B, _, C = data.shape
    grid = (B, C // block_c)
    return pl.pallas_call(
        functools.partial(_per_item_kernel, o=o, j=j, is01=is01),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, o, j), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, j, block_c), lambda b, c: (b, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, o, block_c), lambda b, c: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, o, C), jnp.uint8),
        interpret=interpret,
    )(Ms, data)


@functools.partial(jax.jit,
                   static_argnames=("o", "j", "block_c", "interpret", "is01"))
def _per_item_fold_call(Ms, parity, data, *, o, j, block_c, interpret, is01):
    B, _, C = data.shape
    grid = (B, C // block_c)
    return pl.pallas_call(
        functools.partial(_per_item_fold_kernel, o=o, j=j, is01=is01),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, o, j), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, o, block_c), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, j, block_c), lambda b, c: (b, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, o, block_c), lambda b, c: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, o, C), jnp.uint8),
        interpret=interpret,
    )(Ms, parity, data)


def gf256_matmul_per_item_batched(Ms, blocks, parity=None, *,
                                  block_c: int | None = None,
                                  strategy: str | None = None,
                                  interpret: bool | None = None):
    """Per-item matrices: (B, O, J) (*) (B, J, C) -> (B, O, C).

    Each batch item multiplies by its *own* matrix — the r > 1 (RDP)
    delta shape, where every update folds a (r, r)-per-parity-row system,
    and the fused seal-fold path.  ``parity`` (B, O, C), when given, is
    XORed into the product inside the same kernel (one read stream more,
    one device round trip fewer).  Grid = (batch, C-tiles), like
    ``gf256_matmul_batched``; 0/1 matrices drop the bit-plane loop.
    """
    from repro.kernels import xla_gf256
    Ms = np.asarray(Ms, dtype=np.uint8)
    blocks = jnp.asarray(blocks, dtype=jnp.uint8)
    B, O, J = Ms.shape
    assert blocks.shape[:2] == (B, J), (Ms.shape, blocks.shape)
    C = blocks.shape[2]
    if B == 0 or O == 0:
        return (jnp.asarray(parity, jnp.uint8) if parity is not None
                else jnp.zeros((B, O, C), jnp.uint8))
    dec = dispatch.decide(interpret)
    if dec.path == dispatch.XLA:
        s = strategy if strategy in xla_gf256.STRATEGIES else None
        return xla_gf256.matmul_per_item(Ms, blocks, parity, strategy=s)
    is01 = int(Ms.max(initial=0)) <= 1 and strategy != "cols"
    block_c = min(block_c or DEFAULT_BLOCK_C, _round_up(C, 128))
    Cp = _round_up(C, block_c)
    if Cp != C:
        blocks = jnp.pad(blocks, ((0, 0), (0, 0), (0, Cp - C)))
    Ms_dev = jnp.asarray(Ms.astype(np.int32))
    if parity is None:
        out = _per_item_call(Ms_dev, blocks, o=O, j=J, block_c=block_c,
                             interpret=dec.interpret, is01=is01)
    else:
        parity = jnp.asarray(parity, dtype=jnp.uint8)
        if Cp != C:
            parity = jnp.pad(parity, ((0, 0), (0, 0), (0, Cp - C)))
        out = _per_item_fold_call(Ms_dev, parity, blocks, o=O, j=J,
                                  block_c=block_c, interpret=dec.interpret,
                                  is01=is01)
    return out[:, :, :C]


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult
