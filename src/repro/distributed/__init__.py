"""distributed subpackage."""
