"""Collective building blocks: GF(2^8) scaling, XOR rings, compressed psum.

These run inside `shard_map` bodies.  GF(2^8) scaling by a *static*
coefficient uses the same bit-plane identity as the Pallas kernels
(gamma*x = XOR_b bit_b(x) * (gamma*2^b)) so it is pure shift/and/mul/xor —
VPU-friendly and fusible with the surrounding XORs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gf256
from repro.distributed._compat import axis_size


@functools.lru_cache(maxsize=None)
def _gamma_pows(gamma: int) -> tuple:
    return tuple(int(gf256.MUL_TABLE[gamma, 1 << b]) for b in range(8))


def gf_scale_static(gamma: int, x: jax.Array) -> jax.Array:
    """gamma * x over GF(2^8) for a static gamma; x uint8."""
    if gamma == 0:
        return jnp.zeros_like(x)
    if gamma == 1:
        return x
    xi = x.astype(jnp.int32)
    acc = jnp.zeros_like(xi)
    for b, g in enumerate(_gamma_pows(gamma)):
        acc = acc ^ (((xi >> b) & 1) * g)
    return acc.astype(jnp.uint8)


def ring_shift(x: jax.Array, axis_name: str, shift: int) -> jax.Array:
    """Send x to (rank + shift) mod A; receive from (rank - shift)."""
    A = axis_size(axis_name)
    perm = [(i, (i + shift) % A) for i in range(A)]
    return jax.lax.ppermute(x, axis_name, perm)


def ring_xor_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """XOR-reduce across the axis; result replicated on every member.

    (A-1) ppermute steps; used on the rare recovery path, where the
    masked-contribution + reduce pattern mirrors the paper's decode-from-k.
    """
    A = axis_size(axis_name)
    acc = x
    buf = x

    def body(i, carry):
        acc, buf = carry
        buf = ring_shift(buf, axis_name, 1)
        return acc ^ buf, buf

    acc, _ = jax.lax.fori_loop(0, A - 1, body, (acc, buf))
    return acc


def compressed_psum(x: jax.Array, axis_name: str, *, block: int = 256
                    ) -> jax.Array:
    """int8-quantized sum across an axis (cross-pod gradient compression).

    Per-block absmax scaling; only the int8 payload (+tiny fp32 scales)
    crosses the slow cross-pod links (4x less traffic than fp32 psum).
    Each member's payload keeps its own scale, so the weighted sum is
    exact w.r.t. the quantized values.  The caller owns error feedback
    (see train_step's compression residual).
    """
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    qg = jax.lax.all_gather(q, axis_name)              # (A, nb, block) int8
    sg = jax.lax.all_gather(scale, axis_name)          # (A, nb, 1) fp32
    out = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)  # (nb, block)
    out = out.reshape(-1)[:n].reshape(shape)
    return out
