"""Version-compat shims for jax API drift.

* ``shard_map`` moved out of experimental in 0.8;
* ``jax.lax.axis_size`` only exists on newer jax — older versions spell
  it ``psum(1, axis)`` (statically evaluated to the bound axis size);
* ``jax.sharding.AbstractMesh`` changed its constructor from a single
  ``((name, size), ...)`` shape tuple to ``(axis_sizes, axis_names)``.
"""
from __future__ import annotations


def shard_map(f, mesh, in_specs, out_specs, check_rep: bool = False):
    try:
        import jax
        if hasattr(jax, "shard_map"):
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_rep)
    except TypeError:
        pass
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_rep)


def axis_size(name) -> int:
    """Size of a bound mesh axis inside shard_map/pmap-style code."""
    import jax
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def abstract_mesh(shape, axes):
    """``jax.sharding.AbstractMesh`` across the ctor-signature change."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:  # jax <= 0.4.x wants (("data", 4), ("model", 2))
        return AbstractMesh(tuple(zip(axes, shape)))
