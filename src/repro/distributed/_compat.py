"""Version-compat shim for shard_map (moved out of experimental in 0.8)."""
from __future__ import annotations


def shard_map(f, mesh, in_specs, out_specs, check_rep: bool = False):
    try:
        import jax
        if hasattr(jax, "shard_map"):
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_rep)
    except TypeError:
        pass
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_rep)
