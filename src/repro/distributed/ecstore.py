"""EC in-memory state store: the paper's architecture over the mesh.

MemEC's roles map onto the training mesh's **data axis** (A devices per
model column).  Stripe lists (paper §4.3) become *rotationally symmetric*:

    list l (l = 0..A-1):  data members  (l, l+1, ..., l+k-1) mod A
                          parity row r on device (l+k+r) mod A

On a homogeneous TPU ring the rotation achieves exactly the write-load
balance the paper's greedy generator optimizes for (every device: data
role in k lists, parity role in m lists -> identical load), and it turns
the paper's point-to-point delta unicast into *uniform* `ppermute`
collectives — the TPU-native form of "data server ships gamma*delta to
each parity server" (§2, §4.2).

Layout per device (inside shard_map, fully manual over the mesh):
    local state bytes -> pages (P, page_size) uint8,
    page p: class j = p mod k, stripe s = p div k, list (d - j) mod A;
    parity buffer (m, P//k, page): row r protects list (d - k - r) mod A.

Per train step the optimizer delta (old XOR new) feeds
``parity_delta_update`` — the paper's  P' = P ⊕ gamma (D ⊕ D')  —
with m*k gamma-scaled ppermutes.  Reconstruction of a failed device's
pages is decode-from-k with masked contributions + an XOR-reduce ring
(paper §5.4 degraded GET, at page granularity).

Storage overhead: m/k (25 % for RS(10,8)) vs 100 %+ for replication —
the all-encoding win at fleet scale, since index state (the pytree
structure) is derivable and needs no redundancy (paper §3.2).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from repro.distributed._compat import axis_size, shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import gf256
from repro.core.codes import RSCode

from .collectives import gf_scale_static, ring_shift, ring_xor_reduce


@dataclasses.dataclass(frozen=True)
class ECConfig:
    k: int = 8
    m: int = 2
    page_size: int = 4096
    axis: str = "data"

    @property
    def n(self) -> int:
        return self.k + self.m

    @property
    def code(self) -> RSCode:
        return RSCode(n=self.n, k=self.k)

    @property
    def gamma(self) -> np.ndarray:
        return self.code.parity_matrix  # (m, k)


# ---------------------------------------------------------------------------
# page packing (local, inside shard_map)
# ---------------------------------------------------------------------------

def bytes_of_tree(tree) -> jax.Array:
    """Flatten a pytree's local shards into one uint8 vector."""
    leaves = jax.tree.leaves(tree)
    parts = [jax.lax.bitcast_convert_type(
        x.reshape(-1, 1) if x.dtype == jnp.uint8 else x.reshape(-1),
        jnp.uint8).reshape(-1) for x in leaves]
    return jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.uint8)


def to_pages(flat: jax.Array, cfg: ECConfig) -> jax.Array:
    unit = cfg.k * cfg.page_size
    n = flat.shape[0]
    pad = (-n) % unit
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, cfg.page_size)  # (P, page)


def tree_xor_pages(old_tree, new_tree, cfg: ECConfig) -> jax.Array:
    """(old ⊕ new) as pages — the data delta of the paper's UPDATE."""
    return to_pages(bytes_of_tree(old_tree) ^ bytes_of_tree(new_tree), cfg)


# ---------------------------------------------------------------------------
# core EC ops (inside shard_map; collectives over cfg.axis)
# ---------------------------------------------------------------------------

def parity_delta_update(xor_pages: jax.Array, parity: jax.Array,
                        cfg: ECConfig) -> jax.Array:
    """P' = P ⊕ gamma·(D ⊕ D') routed to rotated parity owners.

    xor_pages: (P, page) local delta; parity: (m, P//k, page) local parity
    buffer.  m*k gamma-scaled ppermutes (shift = (k + r - j) mod A).
    """
    A = axis_size(cfg.axis)
    Pn, page = xor_pages.shape
    S = Pn // cfg.k
    cls = xor_pages.reshape(S, cfg.k, page)
    gamma = cfg.gamma
    rows = []
    for r in range(cfg.m):
        acc = jnp.zeros((S, page), jnp.uint8)
        for j in range(cfg.k):
            contrib = gf_scale_static(int(gamma[r, j]), cls[:, j])
            shift = (cfg.k + r - j) % A
            acc = acc ^ ring_shift(contrib, cfg.axis, shift)
        rows.append(parity[r] ^ acc)
    return jnp.stack(rows)


def parity_delta_update_chain(xor_pages: jax.Array, parity: jax.Array,
                              cfg: ECConfig) -> jax.Array:
    """Systolic variant of `parity_delta_update` (§Perf hillclimb).

    The baseline ships each gamma-scaled contribution directly with a
    shift-(k+r-j) ppermute: on a torus that occupies (k+r-j) links, so the
    per-link traffic is sum_{r,j} (k+r-j) * S pages (= 80*S for RS(10,8)).
    Here partial parities accumulate along a shift-1 ring: at step t every
    device XORs gamma[r,t] * (its class-t delta) into the m bundles passing
    through it, then forwards one hop.  After k steps the row-0 bundle sits
    on its owner; row r forwards r more hops.  Per-link traffic:
    (k + r) hops * m bundles * S pages ≈ 18*S — a 4.4x reduction for
    RS(10,8), at the cost of serializing k+m-1 neighbor hops.
    """
    Pn, page = xor_pages.shape
    S = Pn // cfg.k
    cls = xor_pages.reshape(S, cfg.k, page)
    gamma = cfg.gamma
    bundles = [jnp.zeros((S, page), jnp.uint8) for _ in range(cfg.m)]
    for t in range(cfg.k):
        for r in range(cfg.m):
            bundles[r] = bundles[r] ^ gf_scale_static(int(gamma[r, t]),
                                                      cls[:, t])
        bundles = [ring_shift(b, cfg.axis, 1) for b in bundles]
    # row r travels r extra hops to its owner (l + k + r)
    rows = []
    for r in range(cfg.m):
        b = bundles[r]
        for _ in range(r):
            b = ring_shift(b, cfg.axis, 1)
        rows.append(parity[r] ^ b)
    return jnp.stack(rows)


def encode_parity(pages: jax.Array, cfg: ECConfig) -> jax.Array:
    """Full encode = delta update from an all-zero state."""
    Pn = pages.shape[0]
    parity0 = jnp.zeros((cfg.m, Pn // cfg.k, cfg.page_size), jnp.uint8)
    return parity_delta_update(pages, parity0, cfg)


@functools.lru_cache(maxsize=None)
def _decode_coeffs(k: int, m: int, failed_class: int) -> tuple:
    """Coefficients reconstructing data chunk `failed_class` from the
    surviving k-1 data chunks + parity row 0 (single-device loss)."""
    code = RSCode(n=k + m, k=k)
    avail = [i for i in range(k) if i != failed_class] + [k]
    inv, idx = code.decode_matrix(avail)
    # data = inv @ chunks[idx]; we want row `failed_class`
    coeffs = {pos: int(inv[failed_class, i]) for i, pos in enumerate(idx)}
    return tuple(sorted(coeffs.items()))


@functools.lru_cache(maxsize=None)
def _decode_coeffs_pair(k: int, m: int, want: int, other: int,
                        rows: tuple) -> tuple:
    """Coefficients for data position `want` when data positions
    {want, other} are erased (other = -1 if the second failure holds no
    data chunk in this stripe) using parity rows `rows`."""
    code = RSCode(n=k + m, k=k)
    missing = {want} | ({other} if other >= 0 else set())
    avail = [i for i in range(k) if i not in missing] + \
        [k + r for r in rows]
    inv, idx = code.decode_matrix(avail)
    coeffs = {pos: int(inv[want, i]) for i, pos in enumerate(idx)}
    return tuple(sorted((p, c) for p, c in coeffs.items() if c != 0))


def reconstruct_failed(pages: jax.Array, parity: jax.Array, failed: jax.Array,
                       cfg: ECConfig) -> jax.Array:
    """Rebuild the pages of device `failed` (traced int32 axis index).

    Every device contributes its coefficient-scaled chunk for each stripe
    class, masked to the survivors the decode uses; an XOR ring reduces
    them so the result lands everywhere (the caller slices/uses it on the
    replacement device).  This is degraded GET at page granularity (§5.4).
    """
    A = axis_size(cfg.axis)
    d = jax.lax.axis_index(cfg.axis)
    Pn, page = pages.shape
    S = Pn // cfg.k
    cls = pages.reshape(S, cfg.k, page)
    out = []
    for j in range(cfg.k):
        # list of the failed page-class: l = failed - j
        # this device's data position in that list:
        my_pos = (d - (failed - j)) % A
        coeffs = dict(_decode_coeffs(cfg.k, cfg.m, j))
        contrib = jnp.zeros((S, page), jnp.uint8)
        for pos, coeff in coeffs.items():
            if pos < cfg.k:
                # survivor data member `pos` contributes its class-`pos`
                # pages (its page in list l is its class-(my_pos) slot)
                sel = (my_pos == pos)
                scaled = gf_scale_static(coeff, cls[:, pos])
            else:
                # parity row 0 of list l lives on device l + k
                sel = (my_pos == cfg.k)
                scaled = gf_scale_static(coeff, parity[0])
            contrib = jnp.where(sel, contrib ^ scaled, contrib)
        out.append(ring_xor_reduce(contrib, cfg.axis))
    # out[j]: (S, page) = failed device's class-j pages
    return jnp.stack(out, axis=1).reshape(Pn, page)


def reconstruct_failed_pair(pages: jax.Array, parity: jax.Array,
                            f1: int, f2: int, axis_size: int,
                            cfg: ECConfig) -> jax.Array:
    """Rebuild device f1's pages when devices {f1, f2} are BOTH lost
    (m >= 2 tolerance — the paper's RS(10,8) double failure at fleet
    level).  f1/f2/axis_size are static ints (recovery is a concrete
    coordinator event).  Call twice (swapping f1/f2) to rebuild both.

    Positions are relative to list l = f1 - j: f1 sits at data position
    j, f2 at pos2 = (f2 - f1 + j) mod A (a data member iff pos2 < k),
    parity row r's owner at (k + r) mod A.  Surviving contributions are
    coefficient-scaled, masked, and XOR-ring-reduced (decode-from-k, as
    in the single-failure path).
    """
    A = axis_size
    d = jax.lax.axis_index(cfg.axis)
    Pn, page = pages.shape
    S = Pn // cfg.k
    cls = pages.reshape(S, cfg.k, page)
    out = []
    for j in range(cfg.k):
        pos2 = (f2 - f1 + j) % A
        data_missing = [j] + ([pos2] if pos2 < cfg.k else [])
        failed_pos = {j, pos2}
        rows_avail = [r for r in range(cfg.m)
                      if (cfg.k + r) % A not in failed_pos]
        if len(rows_avail) < len(data_missing):
            raise ValueError(
                f"class {j}: not enough surviving parity rows "
                f"(RS({cfg.n},{cfg.k}) over axis {A}) — stripe "
                "undecodable for this failure pair")
        rows = tuple(rows_avail[: len(data_missing)])
        other = pos2 if pos2 < cfg.k else -1
        coeffs = dict(_decode_coeffs_pair(cfg.k, cfg.m, j, other, rows))
        my_pos = (d - (f1 - j)) % A
        contrib = jnp.zeros((S, page), jnp.uint8)
        for pos, coeff in coeffs.items():
            if pos < cfg.k:
                sel = (my_pos == pos)
                scaled = gf_scale_static(coeff, cls[:, pos])
            else:
                r = pos - cfg.k
                sel = (my_pos == (cfg.k + r) % A)
                scaled = gf_scale_static(coeff, parity[r])
            contrib = jnp.where(sel, contrib ^ scaled, contrib)
        out.append(ring_xor_reduce(contrib, cfg.axis))
    return jnp.stack(out, axis=1).reshape(Pn, page)


# ---------------------------------------------------------------------------
# pytree-level wrappers (build the shard_map around the ops)
# ---------------------------------------------------------------------------

def _flat_specs(tree_specs):
    return tree_specs


class ECStateStore:
    """Erasure-coded in-memory protection of a sharded state pytree.

    Wraps the shard_map plumbing: callers pass auto-sharded pytrees (the
    same ones jit'd train steps use); parity lives as a (A_data, ...)
    device-sharded buffer.
    """

    def __init__(self, mesh: Mesh, state_specs, cfg: ECConfig | None = None):
        self.mesh = mesh
        self.cfg = cfg or ECConfig()
        self.state_specs = state_specs
        axes = mesh.axis_names
        self.extra_axes = [a for a in axes if a != self.cfg.axis]

    def _parity_out_spec(self):
        # parity: (A_data, m, S, page) sharded on the data axis; identical
        # across model/pod columns? No — state differs per model column, so
        # parity carries the model axis too: (A_data, A_model, m, S, page).
        return P(self.cfg.axis, *self.extra_axes)

    def _wrap(self, fn, in_specs, out_specs):
        return shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)

    def local_pages(self, state) -> jax.Array:
        """(A_data, A_other..., P, page) global view of state pages."""
        cfg = self.cfg

        def f(st):
            pages = to_pages(bytes_of_tree(st), cfg)
            shape = (1,) * len(self.mesh.axis_names) + pages.shape
            return pages.reshape(shape)

        out_spec = P(*self.mesh.axis_names, None, None)
        return self._wrap(f, (self.state_specs,), out_spec)(state)

    def encode(self, state) -> jax.Array:
        cfg = self.cfg

        def f(st):
            pages = to_pages(bytes_of_tree(st), cfg)
            par = encode_parity(pages, cfg)
            return par.reshape((1,) * len(self.mesh.axis_names) + par.shape)

        out_spec = P(*self.mesh.axis_names, None, None, None)
        return jax.jit(self._wrap(f, (self.state_specs,), out_spec))(state)

    def delta_update(self, old_state, new_state, parity) -> jax.Array:
        cfg = self.cfg
        axes = self.mesh.axis_names

        def f(old, new, par):
            xor = tree_xor_pages(old, new, cfg)
            par = par.reshape(par.shape[len(axes):])
            out = parity_delta_update(xor, par, cfg)
            return out.reshape((1,) * len(axes) + out.shape)

        spec = P(*axes, None, None, None)
        return jax.jit(self._wrap(
            f, (self.state_specs, self.state_specs, spec), spec))(
                old_state, new_state, parity)

    def reconstruct(self, state, parity, failed_index: int) -> jax.Array:
        """Pages of the failed data-axis position (replicated result)."""
        cfg = self.cfg
        axes = self.mesh.axis_names

        def f(st, par):
            pages = to_pages(bytes_of_tree(st), cfg)
            par = par.reshape(par.shape[len(axes):])
            rec = reconstruct_failed(pages, par,
                                     jnp.int32(failed_index), cfg)
            return rec.reshape((1,) * len(axes) + rec.shape)

        pspec = P(*axes, None, None, None)
        out_spec = P(*axes, None, None)
        return jax.jit(self._wrap(f, (self.state_specs, pspec), out_spec))(
            state, parity)
