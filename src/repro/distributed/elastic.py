"""Elastic fleet runtime: heartbeats, server states, straggler mitigation.

The paper's coordinator state machine (§5.2) lifted to the training
fleet: hosts heartbeat; misses drive NORMAL -> INTERMEDIATE -> DEGRADED;
a restored host passes through COORDINATED_NORMAL while state migrates
back (here: EC reconstruction of its shard pages).  Stragglers (the
transient-failure model of §7.2 — slow, not dead) are detected by an
EWMA step-time threshold and handled by the same degraded transition
*before* they stall the collective — on a synchronous TPU fleet a
straggler delays every step, so eviction-and-reconstruct beats waiting
once expected delay exceeds reconstruction cost.

This module is pure control-plane logic (deterministic, simulated clock
in tests); the data plane it drives is `ecstore.reconstruct` + a mesh
rebuild excluding the failed host.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

from repro.core.coordinator import ServerState


@dataclasses.dataclass
class HostInfo:
    host_id: int
    state: ServerState = ServerState.NORMAL
    last_heartbeat: float = 0.0
    step_time_ewma: float = 0.0
    missed: int = 0


@dataclasses.dataclass
class ElasticConfig:
    heartbeat_interval: float = 1.0
    miss_threshold: int = 3
    straggler_factor: float = 2.5     # x median step time
    ewma_alpha: float = 0.2
    min_hosts: int = 2


@dataclasses.dataclass
class RecoveryPlan:
    kind: str                 # "reconstruct" | "rescale" | "none"
    failed_hosts: list
    new_host_count: int
    notes: str = ""


class FleetMonitor:
    def __init__(self, num_hosts: int, cfg: ElasticConfig | None = None):
        self.cfg = cfg or ElasticConfig()
        self.hosts = {h: HostInfo(h) for h in range(num_hosts)}
        self.transitions: list[tuple[float, int, ServerState]] = []

    # -- signals ---------------------------------------------------------
    def heartbeat(self, host: int, now: float):
        hi = self.hosts[host]
        hi.last_heartbeat = now
        hi.missed = 0
        if hi.state == ServerState.INTERMEDIATE:
            # flapped back before the degraded switch completed
            self._set(host, ServerState.NORMAL, now)

    def report_step_time(self, host: int, step_time: float):
        hi = self.hosts[host]
        a = self.cfg.ewma_alpha
        hi.step_time_ewma = (step_time if hi.step_time_ewma == 0
                             else a * step_time + (1 - a) * hi.step_time_ewma)

    # -- evaluation ---------------------------------------------------------
    def _set(self, host: int, state: ServerState, now: float):
        self.hosts[host].state = state
        self.transitions.append((now, host, state))

    def check(self, now: float) -> RecoveryPlan:
        cfg = self.cfg
        # 1. heartbeat misses
        for hi in self.hosts.values():
            if hi.state in (ServerState.NORMAL, ServerState.COORDINATED_NORMAL):
                misses = int((now - hi.last_heartbeat) / cfg.heartbeat_interval)
                if misses >= cfg.miss_threshold:
                    self._set(hi.host_id, ServerState.INTERMEDIATE, now)
        # 2. stragglers: EWMA vs fleet median
        ewmas = sorted(h.step_time_ewma for h in self.hosts.values()
                       if h.step_time_ewma > 0
                       and h.state == ServerState.NORMAL)
        if ewmas:
            med = ewmas[len(ewmas) // 2]
            for hi in self.hosts.values():
                if (hi.state == ServerState.NORMAL and hi.step_time_ewma
                        > cfg.straggler_factor * max(med, 1e-9)):
                    self._set(hi.host_id, ServerState.INTERMEDIATE, now)
        # 3. resolve INTERMEDIATE -> DEGRADED (inconsistency resolution is
        # instantaneous here: the synchronous step either committed or not)
        failed = []
        for hi in self.hosts.values():
            if hi.state == ServerState.INTERMEDIATE:
                self._set(hi.host_id, ServerState.DEGRADED, now)
            if hi.state == ServerState.DEGRADED:
                failed.append(hi.host_id)
        alive = len(self.hosts) - len(failed)
        if not failed:
            return RecoveryPlan("none", [], alive)
        if alive < self.cfg.min_hosts:
            return RecoveryPlan("rescale", failed, alive,
                                notes="below min_hosts; full restore from "
                                      "disk checkpoint required")
        return RecoveryPlan("reconstruct", failed, alive,
                            notes="EC decode-from-k of failed shards, then "
                                  "rescale mesh")

    # -- restore ------------------------------------------------------------
    def restore(self, host: int, now: float):
        self._set(host, ServerState.COORDINATED_NORMAL, now)

    def migration_done(self, host: int, now: float):
        self._set(host, ServerState.NORMAL, now)

    def states(self) -> dict:
        return {h: hi.state for h, hi in self.hosts.items()}
