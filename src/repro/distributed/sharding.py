"""Sharding rules: param-path -> PartitionSpec over ("pod","data","model").

Strategy (DESIGN.md §6):
* batch -> ("pod","data"); FSDP param+optimizer sharding -> "data";
  tensor parallel -> "model".
* Attention: Q heads -> "model" (GSPMD handles non-divisible head counts
  by padding); KV heads replicated (small); decode KV caches shard the
  *sequence* dim on "model" instead — softmax/contraction over the sharded
  axis becomes the expected all-reduce pair.
* MoE: experts -> "model" (EP); dispatch all-to-all inserted by GSPMD.
* Mamba/RG-LRU: d_inner / recurrent width -> "model".
* vocab -> "model" for embedding + logits.

Rules match on path substrings; first hit wins.  Everything unmatched is
replicated (norms, biases, small vectors).
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _data_axes(mesh: Mesh) -> tuple:
    """FSDP axis (just "data"; pods replicate params for fast recovery)."""
    return ("data",)


def _batch_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# (regex on joined path, spec builder(ndim) -> PartitionSpec)
# paths look like: blocks/0/attn/wq, blocks/2/moe/w_gate, tail/0/mlp/w_up...
def param_rules(cfg: ModelConfig):
    d = "data"
    m = "model"

    def last2(nd, a, b):
        """spec with last two dims (a, b), leading dims (layer-stack) None."""
        return P(*([None] * (nd - 2) + [a, b]))

    def last3(nd, a, b, c):
        return P(*([None] * (nd - 3) + [a, b, c]))

    rules = [
        # embeddings: (V, d)
        (r"embeddings/embed$", lambda nd: P(m, d)),
        (r"embeddings/unembed$", lambda nd: P(d, m)),
        # attention projections: wq/wk/wv (d, H*hd), wo (H*hd, d)
        (r"attn/wq$", lambda nd: last2(nd, d, m)),
        (r"attn/wk$", lambda nd: last2(nd, d, None)),
        (r"attn/wv$", lambda nd: last2(nd, d, None)),
        (r"attn/wo$", lambda nd: last2(nd, m, d)),
        # MLA
        (r"mla/w_dq$", lambda nd: last2(nd, d, None)),
        (r"mla/w_uq$", lambda nd: last3(nd, None, m, None)),
        (r"mla/wq$", lambda nd: last3(nd, d, m, None)),
        (r"mla/w_dkv$", lambda nd: last2(nd, d, None)),
        (r"mla/w_uk$", lambda nd: last3(nd, None, m, None)),
        (r"mla/w_uv$", lambda nd: last3(nd, None, m, None)),
        (r"mla/wo$", lambda nd: last2(nd, m, d)),
        # MLP: (d, f) / (f, d)
        (r"mlp/w_gate$", lambda nd: last2(nd, d, m)),
        (r"mlp/w_up$", lambda nd: last2(nd, d, m)),
        (r"mlp/w_down$", lambda nd: last2(nd, m, d)),
        # MoE: router (d, E); experts (E, d, f)/(E, f, d).  FSDP on the
        # d dim; sharding the non-contracting f instead was tried in §Perf
        # iteration 3 and REFUTED (collective wire unchanged, +20% worse).
        (r"moe/router$", lambda nd: last2(nd, d, None)),
        (r"moe/w_gate$", lambda nd: last3(nd, m, d, None)),
        (r"moe/w_up$", lambda nd: last3(nd, m, d, None)),
        (r"moe/w_down$", lambda nd: last3(nd, m, None, d)),
        # Mamba2
        (r"mamba/in_proj$", lambda nd: last2(nd, d, m)),
        (r"mamba/out_proj$", lambda nd: last2(nd, m, d)),
        (r"mamba/conv_w$", lambda nd: last2(nd, None, m)),
        (r"mamba/conv_b$", lambda nd: P(*([None] * (nd - 1) + [m]))),
        (r"mamba/out_norm", lambda nd: P(*([None] * (nd - 1) + [m]))),
        # RG-LRU
        (r"rglru/w_x$", lambda nd: last2(nd, d, m)),
        (r"rglru/w_gate$", lambda nd: last2(nd, d, m)),
        (r"rglru/(wa|wi)$", lambda nd: last2(nd, None, m)),
        (r"rglru/(ba|bi|lam|conv_b)$", lambda nd: P(*([None] * (nd - 1) + [m]))),
        (r"rglru/conv_w$", lambda nd: last2(nd, None, m)),
        (r"rglru/w_out$", lambda nd: last2(nd, m, d)),
    ]
    return rules


def _mesh_sizes(mesh) -> dict:
    return dict(mesh.shape)


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    sizes = _mesh_sizes(mesh)
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


def fit_spec(spec: P, shape, mesh) -> P:
    """Demote axes that don't divide their dim (manual shard_map and the
    EC page layout need exact divisibility; GSPMD would pad instead).
    Axes absent from the mesh are dropped."""
    sizes = _mesh_sizes(mesh)

    def present(axes):
        if isinstance(axes, str):
            return axes if axes in sizes else None
        kept = tuple(a for a in axes if a in sizes)
        return kept if kept else None

    out = []
    for i, axes in enumerate(spec):
        if axes is not None:
            axes = present(axes)
        if axes is None or i >= len(shape):
            out.append(None if i >= len(shape) else axes)
            continue
        if shape[i] % _axis_size(mesh, axes) == 0 and shape[i] > 0:
            out.append(axes)
        elif not isinstance(axes, str) and axes:
            # tuple axes: try a shrinking prefix, e.g. ("pod","data")->("data",)
            cand = tuple(axes)
            while cand and shape[i] % _axis_size(mesh, cand) != 0:
                cand = cand[1:]
            out.append(cand if cand else None)
        else:
            out.append(None)
    return P(*out)


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(cfg: ModelConfig, params_shape, mesh: Mesh) -> dict:
    """PartitionSpec pytree matching a params (shape-)pytree."""
    rules = param_rules(cfg)

    def spec_for(path, leaf):
        ps = path_str(path)
        nd = len(leaf.shape)
        for pat, builder in rules:
            if re.search(pat, ps):
                spec = builder(nd)
                if len(spec) > nd:  # guard tiny/degenerate leaves
                    return P()
                return fit_spec(spec, leaf.shape, mesh)
        return P()  # replicate

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def batch_specs(cfg: ModelConfig, batch_shape, mesh: Mesh) -> dict:
    """Input batch: leading batch dim -> (pod, data); mrope positions have
    batch second; scalars replicated."""
    b = _batch_axes(mesh)

    def spec_for(path, leaf):
        ps = path_str(path)
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        if "positions" in ps and nd == 3:   # (3, B, S)
            return fit_spec(P(None, b, None), leaf.shape, mesh)
        return fit_spec(P(*([b] + [None] * (nd - 1))), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


def cache_specs(cfg: ModelConfig, cache_shape, mesh: Mesh) -> dict:
    """Decode caches: batch -> (pod,data); the long sequence axis of
    attention KV / MLA latents -> "model" (sequence-sharded decode)."""
    b = _batch_axes(mesh)
    m = "model"

    def spec_for(path, leaf):
        ps = path_str(path)
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        # leading dim may be the layer stack (repeats): detect via path
        off = 1 if ps.startswith("blocks/") else 0
        spec = [None] * nd
        spec[off] = b                       # batch
        if re.search(r"/(k|v|latent|k_rope|k_scale|v_scale)$", ps) \
                and nd >= off + 3:
            spec[off + 1] = m               # sequence axis
        elif re.search(r"/ssm$", ps) and nd >= off + 3:
            spec[off + 1] = m               # ssm heads
        elif re.search(r"/h$", ps):
            spec[off + 1] = m               # rg-lru width
        elif re.search(r"/conv$", ps) and nd >= off + 3:
            spec[off + 2] = m               # conv channels
        return fit_spec(P(*spec), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
